"""Fig 6: accuracy vs evaluation step for six split-inference strategies
under the 5 J / 5 s budget."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_json
from repro.baselines import CMAES, DirectSearch, PPOBaseline, RandomSearch
from repro.core import BasicBO, BayesSplitEdge, default_vgg19_problem


def run(seed: int = 0):
    algos = [
        ("Bayes-Split-Edge", lambda pb: BayesSplitEdge(pb, budget=20)),
        ("Basic-BO", lambda pb: BasicBO(pb, budget=48)),
        ("Direct Search", lambda pb: DirectSearch(pb)),
        ("CMA-ES", lambda pb: CMAES(pb, budget=48)),
        ("Random Search", lambda pb: RandomSearch(pb, budget=48)),
        ("RL (PPO)", lambda pb: PPOBaseline(pb)),
    ]
    traces = {}
    for name, mk in algos:
        pb = default_vgg19_problem()
        res = mk(pb).run(seed=seed)
        traces[name] = dict(acc_per_step=res.accuracies,
                            feasible=res.feasible)
    save_json("fig6_convergence.json", traces)
    return traces


def main():
    traces = run()
    print(f"{'algorithm':18s} {'steps':>5s} {'min%':>6s} {'max%':>6s} "
          f"{'zero-dips':>9s} {'feas%':>6s}")
    for name, t in traces.items():
        acc = np.array(t["acc_per_step"])
        print(f"{name:18s} {len(acc):5d} {acc.min():6.2f} {acc.max():6.2f} "
              f"{(acc == 0).sum():9d} {100*np.mean(t['feasible']):6.1f}")
    return traces


if __name__ == "__main__":
    main()
