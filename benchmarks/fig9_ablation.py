"""Fig 9: acquisition-component ablation — cumulative regret of the full
hybrid vs each component removed (plus our beyond-paper feasible-only-GP
component)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import cumulative_regret, fit_decay_exponent, save_json
from repro.core import BayesSplitEdge, default_vgg19_problem


def _variant(**kw):
    def mk(pb):
        bo = BayesSplitEdge(pb, budget=25, n_max_repeat=10 ** 9, **kw)
        return bo
    return mk


def run(n_seeds: int = 3):
    variants = {
        "full hybrid (ours)": _variant(),
        "no gradient term": _variant(use_grad_term=False),
        "no constraint penalty": _variant(constraint_aware=False),
        "no weight schedules": _variant(use_schedules=False),
    }
    u_star = default_vgg19_problem().exhaustive_optimum(n_power=301)[1]
    out = {}
    for name, mk in variants.items():
        regs, hits = [], []
        for seed in range(n_seeds):
            pb = default_vgg19_problem()
            res = mk(pb).run(seed=seed)
            regs.append(cumulative_regret(res.utilities, u_star))
            hit = next((i + 1 for i, a in enumerate(res.accuracies)
                        if a >= 87.5), None)
            hits.append(hit)
        n = min(len(r) for r in regs)
        avg_cum = np.mean([r[:n] for r in regs], axis=0)
        avg_reg = avg_cum / np.arange(1, n + 1)
        # also ablate the beyond-paper feasible-only GP via flag surgery
        out[name] = dict(cum_regret=avg_cum.tolist(),
                         decay_exponent=fit_decay_exponent(avg_reg),
                         hits=hits)
    # beyond-paper component: GP trained on all (incl. infeasible-0) evals
    regs, hits = [], []
    for seed in range(n_seeds):
        pb = default_vgg19_problem()
        bo = BayesSplitEdge(pb, budget=25, n_max_repeat=10 ** 9)
        bo.gp_feasible_only = False
        res = bo.run(seed=seed)
        regs.append(cumulative_regret(res.utilities, u_star))
        hits.append(next((i + 1 for i, a in enumerate(res.accuracies)
                          if a >= 87.5), None))
    n = min(len(r) for r in regs)
    avg_cum = np.mean([r[:n] for r in regs], axis=0)
    out["GP on all evals (paper's Eq.7 only)"] = dict(
        cum_regret=avg_cum.tolist(),
        decay_exponent=fit_decay_exponent(avg_cum / np.arange(1, n + 1)),
        hits=hits)
    save_json("fig9_ablation.json", out)
    return out


def main():
    out = run()
    print(f"{'variant':38s} {'R_T':>8s} {'decay':>7s} {'hit-iters':>12s}")
    for name, c in out.items():
        print(f"{name:38s} {c['cum_regret'][-1]:8.2f} "
              f"{c['decay_exponent']:7.2f} {str(c['hits']):>12s}")
    return out


if __name__ == "__main__":
    main()
