"""Fig 9: acquisition-component ablation — cumulative regret of the full
hybrid vs each component removed (plus our beyond-paper feasible-only-GP
component). ``--batched`` runs each variant's seed sweep as one vmapped
program via the batched engine (it was the last paper figure still
driving the sequential loop)."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import cumulative_regret, fit_decay_exponent, save_json
from repro.core import (BatchedBayesSplitEdge, BayesSplitEdge, Scenario,
                        default_vgg19_problem)

BUDGET = 25


def _run_variant(n_seeds: int, batched: bool, gp_feasible_only=None, **kw):
    """One ablation variant across seeds — sequential loop or one batched
    engine run. ``gp_feasible_only`` applies the beyond-paper flag surgery
    on either engine."""
    if batched:
        scs = [Scenario(default_vgg19_problem(), seed=seed, budget=BUDGET)
               for seed in range(n_seeds)]
        # routed through the architecture-aware packing (identity on this
        # single-arch equal-budget sweep, but CLI runs now take the same
        # batch-layout path CI's bench gates measure)
        eng = BatchedBayesSplitEdge(scs, n_max_repeat=10 ** 9, pack=True,
                                    **kw)
        if gp_feasible_only is not None:
            eng.gp_feasible_only = gp_feasible_only
        return eng.run()
    out = []
    for seed in range(n_seeds):
        bo = BayesSplitEdge(default_vgg19_problem(), budget=BUDGET,
                            n_max_repeat=10 ** 9, **kw)
        if gp_feasible_only is not None:
            bo.gp_feasible_only = gp_feasible_only
        out.append(bo.run(seed=seed))
    return out


def _curve(results, u_star):
    regs = [cumulative_regret(res.utilities, u_star) for res in results]
    hits = [next((i + 1 for i, a in enumerate(res.accuracies)
                  if a >= 87.5), None) for res in results]
    n = min(len(r) for r in regs)
    avg_cum = np.mean([r[:n] for r in regs], axis=0)
    avg_reg = avg_cum / np.arange(1, n + 1)
    return dict(cum_regret=avg_cum.tolist(),
                decay_exponent=fit_decay_exponent(avg_reg),
                hits=hits)


def run(n_seeds: int = 3, batched: bool = False):
    variants = {
        "full hybrid (ours)": {},
        "no gradient term": dict(use_grad_term=False),
        "no constraint penalty": dict(constraint_aware=False),
        "no weight schedules": dict(use_schedules=False),
    }
    u_star = default_vgg19_problem().exhaustive_optimum(n_power=301)[1]
    out = {}
    for name, kw in variants.items():
        out[name] = _curve(_run_variant(n_seeds, batched, **kw), u_star)
    # beyond-paper component: GP trained on all (incl. infeasible-0) evals
    out["GP on all evals (paper's Eq.7 only)"] = _curve(
        _run_variant(n_seeds, batched, gp_feasible_only=False), u_star)
    save_json("fig9_ablation.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batched", action="store_true",
                    help="vmap each variant's seed sweep on device")
    ap.add_argument("--seeds", type=int, default=3)
    args, _ = ap.parse_known_args()
    out = run(n_seeds=args.seeds, batched=args.batched)
    print(f"{'variant':38s} {'R_T':>8s} {'decay':>7s} {'hit-iters':>12s}")
    for name, c in out.items():
        print(f"{name:38s} {c['cum_regret'][-1]:8.2f} "
              f"{c['decay_exponent']:7.2f} {str(c['hits']):>12s}")
    return out


if __name__ == "__main__":
    main()
